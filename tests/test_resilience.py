"""Fault-tolerant Push-Sum (paper §5 future work): link failures, message
loss, and dead nodes — the mass-conservation algebra under each model, plus
the matrix-level properties of the device fault generator
(:mod:`repro.core.faults`) that the training-path guarantees rest on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import faults as flt
from repro.core import topology as topo
from repro.core.faults import FaultPlan
from repro.core.resilience import FaultySim


def _vals(n=16, d=4, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32))


def test_link_drop_conserves_mass_and_converges():
    x = _vals()
    sim = FaultySim(16, "random", drop_prob=0.3, drop="link", seed=1)
    st = sim.run((x,), 120)
    # exact mass conservation under ack'd links
    assert np.isclose(float(jnp.sum(st.values[0][:, 0])), float(jnp.sum(x[:, 0])), atol=1e-3)
    assert np.isclose(float(jnp.sum(st.weight)), 16.0, atol=1e-3)
    est = st.estimate()[0]
    true = jnp.mean(x, axis=0)
    assert float(jnp.max(jnp.abs(est - true))) < 1e-2


def test_message_drop_estimates_stay_consistent():
    """Lost messages lose mass, but every node's v/w ratio remains a convex
    combination of initial values (no double counting) — node estimates
    stay within the convex hull of the inputs."""
    x = _vals(seed=2)
    sim = FaultySim(16, "random", drop_prob=0.2, drop="message", seed=3)
    st = sim.run((x,), 80)
    est = np.asarray(st.estimate()[0])
    lo, hi = np.asarray(x).min(0), np.asarray(x).max(0)
    assert np.all(est >= lo - 1e-4) and np.all(est <= hi + 1e-4)
    # mass strictly lost
    assert float(jnp.sum(st.weight)) < 16.0


def test_dead_nodes_freeze_but_survivors_agree():
    x = _vals(seed=4)
    sim = FaultySim(16, "random", dead_nodes=(3, 7), seed=5)
    st = sim.run((x,), 150)
    est = np.asarray(st.estimate()[0])
    # dead nodes keep their initial value
    assert np.allclose(est[3], np.asarray(x)[3], atol=1e-5)
    assert np.allclose(est[7], np.asarray(x)[7], atol=1e-5)
    # survivors reach consensus among themselves
    alive = [i for i in range(16) if i not in (3, 7)]
    spread = est[alive].max(0) - est[alive].min(0)
    assert float(spread.max()) < 1e-2


def test_zero_drop_matches_clean_pushsum():
    from repro.core.push_sum import PushSumSim
    x = _vals(seed=6)
    a = FaultySim(8, "random", drop_prob=0.0, seed=7).run((x[:8],), 40)
    b = PushSumSim(8, "random", seed=7).run((x[:8],), 40)
    assert np.allclose(np.asarray(a.estimate()[0]), np.asarray(b.estimate()[0]), atol=1e-5)


# ---------------------------------------------------------------------------
# Matrix-level properties of the device fault generator
# ---------------------------------------------------------------------------
# Convention reminder: B[i, j] is the share node i pushes to node j and one
# round applies x' = B^T x, so *row* sums of B are each sender's outgoing
# mass — row-stochasticity is exactly mass conservation.


def _clean(topology, m, t, seed=0):
    rng = np.random.default_rng((seed, t)) if topology == "random" else None
    return topo.build_matrix(topology, m, t=t, rng=rng)


@pytest.mark.parametrize("topology", ["exponential", "random"])
def test_link_mode_rows_stochastic_exactly(topology):
    """Link-mode faulty matrices stay row-stochastic for every draw — the
    sender keeps each undeliverable share, so conservation is exact, not
    statistical."""
    m = 8
    plan = flt.validate_plan(FaultPlan(drop_prob=0.4, drop="link",
                                       dead_nodes=(2,), seed=11), m)
    for t in range(20):
        B = flt.faulty_matrix_host(_clean(topology, m, t), plan, t)
        np.testing.assert_allclose(B.sum(axis=1), np.ones(m), atol=1e-6)
        assert np.all(B >= 0)


@pytest.mark.parametrize("topology", ["exponential", "random"])
def test_message_mode_leakage_bounded_by_drop_prob(topology):
    """Message-mode rows sum to < 1 only by what failed links carried: each
    row keeps at least its diagonal self-share (the diagonal never fails),
    and the *average* leaked fraction matches drop_prob x (off-diagonal
    mass) to statistical tolerance."""
    m = 8
    p = 0.25
    plan = flt.validate_plan(FaultPlan(drop_prob=p, drop="message", seed=12), m)
    leaked, offdiag = [], []
    for t in range(300):
        B0 = _clean(topology, m, t)
        B = flt.faulty_matrix_host(B0, plan, t)
        assert np.all(B.sum(axis=1) <= 1.0 + 1e-6)
        # the self-share survives every draw
        assert np.all(np.diag(B) >= np.diag(B0) - 1e-6)
        leaked.append(1.0 - B.sum(axis=1))
        offdiag.append(B0.sum(axis=1) - np.diag(B0))
    rate = np.mean(leaked) / np.mean(offdiag)
    assert rate == pytest.approx(p, abs=0.02), rate


def test_dead_rows_collapse_and_inbound_links_fail():
    m = 6
    for drop in ("link", "message"):
        plan = flt.validate_plan(
            FaultPlan(drop_prob=0.0, drop=drop, dead_nodes=(1, 4), seed=0), m)
        B = flt.faulty_matrix_host(_clean("exponential", m, 3), plan, 3)
        for d in (1, 4):
            np.testing.assert_array_equal(B[d], np.eye(m, dtype=B.dtype)[d])
            # nothing is delivered *to* a dead node either
            off = np.delete(B[:, d], d)
            np.testing.assert_array_equal(off, np.zeros(m - 1, B.dtype))
        if drop == "link":  # shares into the dead nodes returned to senders
            np.testing.assert_allclose(B.sum(axis=1), np.ones(m), atol=1e-6)


def test_dead_node_mass_frozen_through_rounds():
    """A dead node's Push-Sum mass weight stays exactly at its initial value
    through arbitrarily many faulty rounds (its row is e_d and inbound links
    fail), and its value mass never moves."""
    m, d = 8, 3
    x = _vals(n=m, d=d, seed=9)
    sim = FaultySim(m, "exponential", drop_prob=0.3, drop="link",
                    dead_nodes=(5,), seed=6)
    st = sim.run((x,), 60)
    assert float(st.weight[5]) == 1.0
    np.testing.assert_array_equal(np.asarray(st.values[0][5]), np.asarray(x[5]))


@pytest.mark.parametrize("topology", ["exponential", "random"])
def test_host_and_device_fault_matrices_identical(topology):
    """The pinning test behind 'one fault model': FaultySim's host matrix and
    the jitted on-device faulty_rounds stack are byte-identical at fixed
    seeds — whatever the simulator validates transfers verbatim to the fused
    trainer."""
    m, R = 8, 3
    sim = FaultySim(m, topology, drop_prob=0.35, drop="message",
                    dead_nodes=(0, 3), seed=21)
    for t in (1, 7, 19):
        clean = np.stack([_clean(topology, m, t, seed=21) if topology == "random"
                          else _clean(topology, m, t) for _ in range(1)])
        # r=0 slice via the host shell...
        host = flt.faulty_matrix_host(clean[0], sim.plan, t, r=0)
        # ...vs the device vmap the training step folds
        dev = np.asarray(jax.jit(
            lambda Bs: flt.faulty_rounds(Bs, sim.plan, t))(
                jnp.asarray(np.broadcast_to(clean[0], (R, m, m)))))
        np.testing.assert_array_equal(host, dev[0])
        # FaultySim.matrix goes through the same path end to end
        if topology == "random":
            np.testing.assert_array_equal(sim.matrix(t), host)


def test_validate_plan_errors_and_normalization():
    with pytest.raises(ValueError, match="drop mode"):
        flt.validate_plan(FaultPlan(drop="udp"), 4)
    with pytest.raises(ValueError, match="drop_prob"):
        flt.validate_plan(FaultPlan(drop_prob=1.0), 4)
    with pytest.raises(ValueError, match="dead_nodes"):
        flt.validate_plan(FaultPlan(dead_nodes=(4,)), 4)
    with pytest.raises(ValueError, match="all 4 nodes dead"):
        flt.validate_plan(FaultPlan(dead_nodes=(0, 1, 2, 3)), 4)
    norm = flt.validate_plan(
        FaultPlan(drop_prob=np.float64(0.2), dead_nodes=(3, 1, 3)), 4)
    assert norm.dead_nodes == (1, 3) and isinstance(norm.drop_prob, float)
    # canonical plans hash equal -> shared jit cache entries
    assert norm == flt.validate_plan(FaultPlan(drop_prob=0.2,
                                               dead_nodes=(1, 3, 1)), 4)
