"""Overload policy: bounded admission, deadlines, shedding, degradation.

Covers ``docs/ARCHITECTURE.md`` §9 end to end — the typed admission
outcomes (:class:`~repro.serve.QueryRejected` / :class:`~repro.serve.Shed`
/ :class:`~repro.serve.DeadlineExceeded`), the accounting invariant
``submitted == delivered + shed + deadline_missed + pending``, deadline
expiry against an injectable clock (property-tested), the hysteretic
:class:`~repro.serve.DegradeLadder`, and the training-side non-finite
guards (``NonFiniteWeightsError`` from both train paths and the
publisher's refusal to ship a NaN plane).
"""
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import serve
from repro import telemetry as tm
from repro.core.gadget import (GadgetConfig, NonFiniteWeightsError,
                               SegmentResult, gadget_train,
                               gadget_train_stream)

RNG = np.random.default_rng(0)


def _ok(b, cols, vals):
    """Trivial score_fn: zeros, labels all +1."""
    return np.zeros(b.rows), np.ones(b.rows)


def _buckets(rows=2, k=4):
    return (serve.Bucket(rows, k, rows * k),)


def _query(nnz=2, d=64, rng=RNG):
    cols = np.sort(rng.choice(d, size=nnz, replace=False)).astype(np.int32)
    return cols, rng.normal(size=nnz).astype(np.float32)


def _reconciles(mb):
    st = mb.stats()
    assert st["submitted"] == (st["delivered"] + st["shed"]
                               + st["deadline_missed"] + st["pending"]), st
    return st


# ------------------------------------------------------- bounded admission


class TestBoundedAdmission:
    def test_reject_new_raises_typed_and_enqueues_nothing(self):
        mb = serve.MicroBatcher(_buckets(), max_pending=2,
                                admission="reject-new")
        for _ in range(2):
            mb.submit(*_query())
        with pytest.raises(serve.QueryRejected) as ei:
            mb.submit(*_query())
        assert ei.value.reason == "queue-full"
        assert ei.value.pending == 2 and ei.value.max_pending == 2
        assert isinstance(ei.value, ValueError)  # pre-typed callers keep working
        assert mb.pending == 2
        st = _reconciles(mb)
        assert st["rejected"] == 1 and st["submitted"] == 2
        mb.drain(_ok)
        mb.submit(*_query())  # drain freed the queue
        assert mb.pending == 1

    def test_shed_oldest_delivers_typed_shed_results(self):
        mb = serve.MicroBatcher(_buckets(), max_pending=3,
                                admission="shed-oldest")
        rids = [mb.submit(*_query()) for _ in range(5)]  # sheds rids[0], rids[1]
        assert mb.pending == 3
        out = mb.drain(_ok)
        assert sorted(out) == sorted(rids)  # every accepted request has a fate
        for rid in rids[:2]:
            r = out[rid]
            assert isinstance(r, serve.Shed)
            assert r.rid == rid and r.reason == "shed-oldest"
            assert r.t_shed >= r.t_submit
        for rid in rids[2:]:
            scores, label = out[rid]
            assert label == 1.0
        st = _reconciles(mb)
        assert st["shed"] == 2 and st["delivered"] == 3
        assert st["queue_peak"] == 3

    def test_block_waits_for_drain_to_free_a_slot(self):
        mb = serve.MicroBatcher(_buckets(), max_pending=1, admission="block")
        mb.submit(*_query())
        got = []

        def bg():
            got.append(mb.submit(*_query()))

        th = threading.Thread(target=bg, daemon=True)
        th.start()
        time.sleep(0.05)
        assert not got and mb.pending == 1  # submitter parked, nothing lost
        mb.drain(_ok)  # frees the slot and notifies
        th.join(timeout=5.0)
        assert not th.is_alive() and len(got) == 1
        assert mb.pending == 1
        _reconciles(mb)

    def test_block_timeout_raises_typed(self):
        mb = serve.MicroBatcher(_buckets(), max_pending=1, admission="block",
                                block_timeout=0.05)
        mb.submit(*_query())
        t0 = time.monotonic()
        with pytest.raises(serve.QueryRejected) as ei:
            mb.submit(*_query())
        assert ei.value.reason == "block-timeout"
        assert time.monotonic() - t0 >= 0.04
        assert mb.pending == 1
        assert mb.stats()["rejected"] == 1

    def test_admission_knob_validation(self):
        with pytest.raises(ValueError, match="admission"):
            serve.MicroBatcher(_buckets(), admission="drop-all")
        with pytest.raises(ValueError, match="max_pending"):
            serve.MicroBatcher(_buckets(), max_pending=0)
        with pytest.raises(ValueError, match="default_timeout"):
            serve.MicroBatcher(_buckets(), default_timeout=0.0)

    def test_unbounded_batcher_never_sheds(self):
        mb = serve.MicroBatcher(_buckets())  # historical behavior preserved
        rids = [mb.submit(*_query()) for _ in range(50)]
        out = mb.drain(_ok)
        assert sorted(out) == sorted(rids)
        st = _reconciles(mb)
        assert st["shed"] == st["rejected"] == st["deadline_missed"] == 0


# -------------------------------------------------------- typed rejection


class TestOversizeRejection:
    def test_oversize_carries_nnz_and_widest_k(self):
        mb = serve.MicroBatcher(_buckets(k=4))
        with pytest.raises(serve.QueryRejected) as ei:
            mb.submit(np.arange(6), np.ones(6))
        assert ei.value.reason == "oversize"
        assert ei.value.nnz == 6 and ei.value.k_max == 4
        assert isinstance(ei.value, ValueError)
        assert "widest bucket" in str(ei.value)
        assert mb.stats()["rejected"] == 1
        assert mb.pending == 0

    def test_submit_csr_all_or_nothing_on_oversize_mid_chunk(self):
        """Regression: an oversize row in the middle of a CSR chunk used to
        leave the rows before it enqueued; now the whole chunk is validated
        before anything is admitted."""
        from scipy.sparse import csr_matrix
        d = 64
        rows = [np.zeros(d, np.float32) for _ in range(5)]
        for i, r in enumerate(rows):
            r[: 2 + (6 if i == 2 else 0)] = 1.0  # row 2 has nnz 8 > k=4
        csr = csr_matrix(np.stack(rows))
        mb = serve.MicroBatcher(_buckets(k=4))
        with pytest.raises(serve.QueryRejected) as ei:
            mb.submit_csr(csr)
        assert ei.value.reason == "oversize" and ei.value.nnz == 8
        assert mb.pending == 0, "oversize mid-chunk must enqueue nothing"
        assert mb.stats()["submitted"] == 0
        # the same chunk minus the bad row enqueues fully
        good = csr_matrix(np.stack(rows[:2] + rows[3:]))
        rids = mb.submit_csr(good)
        assert len(rids) == 4 and mb.pending == 4


# ------------------------------------------------------------- deadlines


class TestDeadlines:
    def _clocked(self, **kw):
        clock = {"t": 0.0}
        mb = serve.MicroBatcher(_buckets(), clock=lambda: clock["t"], **kw)
        return mb, clock

    def test_expired_request_never_reaches_score_fn(self):
        mb, clock = self._clocked()
        rid = mb.submit(*_query(), deadline=5.0)
        clock["t"] = 6.0
        calls = []

        def spy(b, cols, vals):
            calls.append(1)
            return _ok(b, cols, vals)

        out = mb.drain(spy)
        assert not calls, "expired work must be dropped before launch"
        r = out[rid]
        assert isinstance(r, serve.DeadlineExceeded)
        assert r.rid == rid and r.deadline == 5.0 and r.t_expired == 6.0
        st = _reconciles(mb)
        assert st["deadline_missed"] == 1 and st["delivered"] == 0

    def test_default_timeout_sets_deadline(self):
        mb, clock = self._clocked(default_timeout=2.0)
        rid_dead = mb.submit(*_query())            # deadline = 2.0
        rid_live = mb.submit(*_query(), deadline=10.0)  # explicit override
        clock["t"] = 3.0
        out = mb.drain(_ok)
        assert isinstance(out[rid_dead], serve.DeadlineExceeded)
        assert isinstance(out[rid_live], tuple)
        _reconciles(mb)

    def test_live_request_scored_before_deadline(self):
        mb, clock = self._clocked()
        rid = mb.submit(*_query(), deadline=5.0)
        clock["t"] = 4.99
        out = mb.drain(_ok)
        scores, label = out[rid]
        assert label == 1.0
        assert mb.stats()["deadline_missed"] == 0

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**32 - 1))
    def test_deadline_expiry_property(self, seed):
        """Random submit/advance/drain schedules against an injectable clock:
        a request expires iff its deadline has passed at drain time, every
        rid gets exactly one result, and the accounting reconciles after
        every drain."""
        rng = np.random.default_rng(seed)
        mb, clock = self._clocked()
        open_reqs = {}   # rid -> deadline (None = immortal)
        results = {}
        for _ in range(rng.integers(5, 30)):
            op = rng.integers(0, 3)
            if op == 0:
                dl = (None if rng.integers(2) == 0
                      else clock["t"] + float(rng.integers(0, 5)))
                rid = mb.submit(*_query(rng=rng), deadline=dl)
                open_reqs[rid] = dl
            elif op == 1:
                clock["t"] += float(rng.integers(0, 4))
            else:
                now = clock["t"]
                out = mb.drain(_ok)
                assert sorted(out) == sorted(open_reqs), "one result per rid"
                for rid, dl in open_reqs.items():
                    expired = dl is not None and now >= dl
                    assert isinstance(out[rid], serve.DeadlineExceeded) \
                        == expired, (rid, dl, now)
                assert not (set(out) & set(results)), "no duplicate results"
                results.update(out)
                open_reqs.clear()
                _reconciles(mb)
        out = mb.drain(_ok)
        results.update(out)
        assert sorted(out) == sorted(open_reqs)
        st = _reconciles(mb)
        assert st["submitted"] == len(results) and st["pending"] == 0


# ------------------------------------------------- failure redelivery, soak


class TestDrainRobustness:
    def test_repeated_score_failures_redeliver_everything_once(self):
        """_undelivered carryover across *consecutive* failing drains: held
        results survive any number of failures and every rid is delivered
        exactly once in the end."""
        mb = serve.MicroBatcher(_buckets())
        rids = [mb.submit(*_query()) for _ in range(8)]  # 4 batches of 2
        fail_times = 3
        state = {"calls": 0, "fails": 0}

        def flaky(b, cols, vals):
            state["calls"] += 1
            if state["calls"] % 2 == 0 and state["fails"] < fail_times:
                state["fails"] += 1
                raise RuntimeError("boom")
            return _ok(b, cols, vals)

        delivered = {}
        for _ in range(fail_times):
            with pytest.raises(RuntimeError, match="boom"):
                mb.drain(flaky)
            assert mb.pending > 0  # failed + unreached batches requeued
        out = mb.drain(flaky)
        assert not (set(out) & set(delivered))
        delivered.update(out)
        assert sorted(delivered) == sorted(rids)
        st = _reconciles(mb)
        assert st["delivered"] == 8 and st["pending"] == 0

    def test_shedding_soak_flat_memory(self):
        """50k submissions against a 64-slot queue: pending never exceeds the
        bound, the result ledger drains fully, and batcher memory stays flat
        (bounded histograms + bounded queue — no per-request growth)."""
        import tracemalloc
        mb = serve.MicroBatcher(_buckets(rows=4, k=4), max_pending=64,
                                admission="shed-oldest")
        cols = np.array([1, 2], np.int32)
        vals = np.array([1.0, 0.5], np.float32)

        def pump(n):
            for i in range(n):
                mb.submit(cols, vals)
                assert mb.pending <= 64
                if i % 512 == 0:
                    mb.drain(_ok)
            mb.drain(_ok)

        pump(10_000)  # warm every structure before measuring
        tracemalloc.start()
        base, _ = tracemalloc.get_traced_memory()
        pump(40_000)
        now, _ = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        assert now - base < 256 * 1024, (
            f"batcher grew {(now - base) / 1024:.0f} KiB over 40k submissions")
        st = _reconciles(mb)
        assert st["submitted"] == 50_000 and st["pending"] == 0
        assert st["queue_peak"] <= 64
        assert st["delivered"] + st["shed"] == 50_000


# ------------------------------------------------------- degradation ladder


class TestDegradeLadder:
    def _rig(self, d=256, max_pending=4):
        W = np.random.default_rng(3).standard_normal(d).astype(np.float32)
        srv = serve.SvmServer(W)
        buckets = serve.bucket_ladder(16, rows=2, min_k=4, d=d)
        mb = serve.MicroBatcher(buckets, max_pending=max_pending,
                                admission="shed-oldest")
        lad = serve.DegradeLadder(srv, mb, high=0.75, low=0.25, patience=2)
        return srv, mb, lad

    def _fill(self, mb, n):
        for _ in range(n):
            mb.submit(*_query(nnz=2, d=256))

    def test_hysteresis_steps_down_and_recovers(self):
        srv, mb, lad = self._rig()
        lad.prepare()
        assert srv.plane == "f32" and not srv.degraded
        self._fill(mb, 4)  # pressure 1.0
        assert lad.observe() == 0  # patience 2: first breach arms only
        assert lad.observe() == 1  # rung 1: int8 plane
        assert srv.plane == "int8" and srv.degraded
        assert srv.stats()["degraded"] == 1
        assert lad.observe() == 1
        assert lad.observe() == 2  # rung 2: + cheapest bucket
        assert mb._degraded_bucket == mb.buckets[0]
        assert lad.observe() == 2  # capped at max_rung
        mb.drain(srv.scorer_for())  # pressure -> 0
        assert lad.observe() == 2
        assert lad.observe() == 1  # recovery is also hysteretic
        assert lad.observe() == 1
        assert lad.observe() == 0
        assert srv.plane == "f32" and mb._degraded_bucket is None
        reg = srv.registry
        assert reg.value("serve.degrade_steps", direction="down") == 2
        assert reg.value("serve.degrade_steps", direction="up") == 2

    def test_in_band_pressure_resets_streaks(self):
        srv, mb, lad = self._rig(max_pending=4)
        self._fill(mb, 4)
        lad.observe()           # above-streak 1
        mb.drain(srv.scorer_for())
        self._fill(mb, 2)       # pressure 0.5: inside the hysteresis band
        lad.observe()           # resets the streak
        self._fill(mb, 2)       # back to 1.0
        assert lad.observe() == 0, "band must reset the above-streak"
        assert lad.observe() == 1

    def test_degraded_routing_truncates_to_top_abs_values(self):
        srv, mb, lad = self._rig()
        lad.prepare()
        mb.degrade_to(mb.buckets[0])  # k=4
        srv.set_plane("int8")
        cols = np.arange(8, dtype=np.int32)
        vals = np.array([0.1, -3.0, 0.2, 2.0, -0.3, 1.0, 0.4, -2.5],
                        np.float32)
        rid = mb.submit(cols, vals)
        out = mb.drain(srv.scorer_for())
        scores, _ = out[rid]
        w = np.asarray(srv._planes["int8"])
        keep = np.argsort(-np.abs(vals))[:4]  # |val| top-4: -3, -2.5, 2, 1
        want = float(np.dot(w[cols[keep]], vals[keep]))
        np.testing.assert_allclose(np.asarray(scores).reshape(()), want,
                                   rtol=1e-5)
        assert mb.stats()["truncated"] == 1

    def test_ladder_transitions_never_recompile(self):
        srv, mb, lad = self._rig()
        lad.prepare()
        score_fn = srv.scorer_for()
        for _ in range(3):  # touch every bucket at full service
            self._fill(mb, 4)
            mb.drain(score_fn)
        shapes0 = srv.stats()["distinct_shapes"]
        self._fill(mb, 4)
        for _ in range(4):
            lad.observe()
        assert lad.rung == 2
        mb.drain(score_fn)
        for _ in range(6):
            lad.observe()
        assert lad.rung == 0
        self._fill(mb, 4)
        mb.drain(score_fn)
        assert srv.stats()["distinct_shapes"] == shapes0
        assert srv.stats()["plane_swaps"] >= 2

    def test_hot_swap_requantizes_degraded_plane(self):
        """Publisher hot-swap composes with overload: a weight swap while the
        ladder is on the int8 rung re-quantizes the *new* weights."""
        srv, mb, lad = self._rig(d=64)
        srv.set_plane("int8")
        W2 = np.full(64, 2.0, np.float32)
        srv.swap_weights(W2)
        assert srv.plane == "int8"
        q = serve.quantize_int8(W2)
        np.testing.assert_array_equal(np.asarray(srv._planes["int8"]),
                                      serve.dequantize_int8(*q))
        srv.set_plane("f32")
        np.testing.assert_array_equal(np.asarray(srv._W_dev), W2)

    def test_set_plane_validates(self):
        srv, _, _ = self._rig(d=64)
        with pytest.raises(ValueError, match="plane"):
            srv.set_plane("fp4")

    def test_ladder_knob_validation(self):
        srv, mb, _ = self._rig(d=64)
        with pytest.raises(ValueError, match="low < high"):
            serve.DegradeLadder(srv, mb, high=0.2, low=0.5)
        with pytest.raises(ValueError, match="patience"):
            serve.DegradeLadder(srv, mb, patience=0)
        with pytest.raises(ValueError, match="max_rung"):
            serve.DegradeLadder(srv, mb, max_rung=3)


# ---------------------------------------------------- non-finite training


class TestNonFiniteGuards:
    def _data(self, poison=True):
        rng = np.random.default_rng(5)
        m, n, d = 2, 8, 16
        X = rng.normal(size=(m, n, d)).astype(np.float32)
        if poison:
            X[0] = np.nan  # every node-0 row: w goes NaN on its first step
        y = np.where(rng.integers(0, 2, size=(m, n)) == 0, -1.0, 1.0)
        return X, y.astype(np.float32)

    def _cfg(self, **kw):
        kw.setdefault("check_every", 4)
        return GadgetConfig(lam=0.1, batch_size=4, gossip_rounds=1,
                            topology="ring", max_iters=12, epsilon=0.0, **kw)

    def test_gadget_train_raises_typed_with_iteration(self):
        tm.reset()
        X, y = self._data()
        with pytest.raises(NonFiniteWeightsError) as ei:
            gadget_train(X, y, self._cfg())
        assert 1 <= ei.value.iteration <= 12
        assert ei.value.context == "training"
        assert isinstance(ei.value, FloatingPointError)
        assert tm.default_registry().value("train.nonfinite") == 1

    def test_clean_training_untouched(self):
        tm.reset()
        X, y = self._data(poison=False)
        res = gadget_train(X, y, self._cfg())
        assert np.all(np.isfinite(np.asarray(res.w_consensus)))
        assert tm.default_registry().get("train.nonfinite") is None

    def test_stream_raises_at_segment_boundary(self):
        tm.reset()
        X, y = self._data()
        with pytest.raises(NonFiniteWeightsError) as ei:
            for _ in gadget_train_stream(X, y, self._cfg(), segment_iters=4):
                pass
        assert ei.value.iteration >= 1
        assert tm.default_registry().value("train.nonfinite") == 1

    def test_publisher_refuses_nonfinite_segment(self, tmp_path):
        X, y = self._data(poison=False)
        pub = serve.TrainPublisher(X, y, self._cfg(), root=str(tmp_path),
                                   segment_iters=4)
        bad = SegmentResult(iteration=3, W=None,
                            w_consensus=np.full(16, np.nan, np.float32),
                            objective=float("nan"), epsilon=0.0, done=False)
        with pytest.raises(NonFiniteWeightsError) as ei:
            pub._publish(bad)
        assert ei.value.context == "publish"
        assert pub.published == []
        assert pub.registry.value("publish.nonfinite") == 1
