"""Flight-recorder telemetry: registry primitives, histogram properties
(hypothesis — merge associativity, quantile bounds vs a sorted-array oracle),
exporters + dump CLI, kernel launch accounting, the batcher soak (flat
memory), and the load-bearing guarantee that ``telemetry=None`` traces the
exact pre-telemetry training program (bit-identical trajectories on the
dense, sparse, faulty, and streaming paths)."""
import math
import os
import subprocess
import sys
import threading
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
from repro import telemetry as tm
from repro.core.faults import FaultPlan
from repro.core.gadget import GadgetConfig, gadget_train, gadget_train_stream
from repro.data import svm_datasets
from repro.kernels.hinge_subgrad import ops as hinge_ops
from repro.serve import batcher as bat
from repro.telemetry import dump as tm_dump
from repro.telemetry.registry import Histogram, Registry

REPO = Path(__file__).resolve().parent.parent


def _toy_parts(m=4, n_i=16, d=24, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=d)
    X = rng.normal(size=(m * n_i, d)).astype(np.float32)
    y = np.sign(X @ w_true).astype(np.float32)
    return jnp.asarray(X.reshape(m, n_i, d)), jnp.asarray(y.reshape(m, n_i))


def _cfg(**kw):
    base = dict(lam=1e-2, batch_size=2, gossip_rounds=2, max_iters=16,
                check_every=4, epsilon=0.0, use_kernels=False)
    base.update(kw)
    return GadgetConfig(**base)


def _hist(**kw):
    base = dict(base=1e-4, growth=2.0 ** 0.25, n_buckets=96)
    base.update(kw)
    return Histogram("h", {}, threading.RLock(), **base)


# ---------------------------------------------------------------------------
# Registry primitives
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_counter_gauge_basics(self):
        reg = Registry()
        reg.counter("a").inc().inc(2.5)
        assert reg.value("a") == 3.5
        with pytest.raises(ValueError):
            reg.counter("a").inc(-1)
        reg.gauge("g").set(4.0)
        reg.gauge("g").inc(-1.5)
        assert reg.value("g") == 2.5

    def test_labels_key_distinct_series_and_identity(self):
        reg = Registry()
        a = reg.counter("kernel.launches", kernel="dense_predict").inc()
        b = reg.counter("kernel.launches", kernel="ell_predict").inc(5)
        assert a is reg.counter("kernel.launches", kernel="dense_predict")
        assert a is not b
        assert reg.values() == {
            "kernel.launches{kernel=dense_predict}": 1.0,
            "kernel.launches{kernel=ell_predict}": 5.0,
        }

    def test_kind_mismatch_rejected(self):
        reg = Registry()
        reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_value_defaults_zero_and_reset(self):
        reg = Registry()
        assert reg.value("never.touched") == 0.0
        reg.counter("x").inc()
        reg.reset()
        assert reg.get("x") is None

    def test_span_times_into_histogram_and_emits(self):
        t = [0.0]

        def clock():
            t[0] += 0.25
            return t[0]

        events = []
        reg = Registry(clock=clock)
        reg.attach_sink(type("S", (), {"emit": staticmethod(events.append)}))
        with reg.span("phase.seconds", step=3) as sp:
            pass
        assert sp.seconds == pytest.approx(0.25)
        assert reg.get("phase.seconds").count == 1
        (ev,) = events
        assert ev["kind"] == "span" and ev["fields"] == {"step": 3}
        assert "ts" in ev
        reg.detach_sink()
        with reg.span("phase.seconds"):
            pass
        assert len(events) == 1

    def test_default_registry_conveniences(self):
        tm.reset()
        tm.counter("c").inc(2)
        tm.gauge("g").set(1.0)
        assert tm.default_registry().values() == {"c": 2.0, "g": 1.0}
        tm.reset()


# ---------------------------------------------------------------------------
# Histogram properties (hypothesis)
# ---------------------------------------------------------------------------


class TestHistogram:
    def test_ladder_validation(self):
        with pytest.raises(ValueError):
            _hist(base=0.0)
        with pytest.raises(ValueError):
            _hist(growth=1.0)
        with pytest.raises(ValueError):
            _hist(n_buckets=1)

    def test_empty_reads(self):
        h = _hist()
        assert math.isnan(h.quantile(0.5)) and math.isnan(h.value)
        assert h.count == 0 and h.min == math.inf and h.max == -math.inf

    @given(st.integers(2, 90))
    def test_edges_belong_to_bucket_below(self, j):
        h = _hist()
        edge = h.upper_edge(j)
        assert h.bucket_index(edge) == j
        assert h.bucket_index(edge * 1.0001) == j + 1

    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 400))
    @settings(max_examples=30, deadline=None)
    def test_quantile_brackets_sorted_oracle(self, seed, n):
        """For every quantile: oracle <= histogram <= oracle * growth, with
        the two documented exceptions (bucket 0 reports ``base``, overflow
        reports the exact tracked max)."""
        rng = np.random.default_rng(seed)
        samples = rng.lognormal(mean=-2.0, sigma=3.0, size=n)
        h = _hist()
        for v in samples:
            h.observe(v)
        s = np.sort(samples)
        for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0):
            oracle = float(s[max(1, math.ceil(q * n)) - 1])
            got = h.quantile(q)
            assert oracle <= got * (1 + 1e-9), (q, oracle, got)
            if got == h.base:
                assert oracle <= h.base
            elif got == h.max and h.bucket_index(h.max) == h.n_buckets - 1:
                pass  # overflow: exact max, arbitrarily far above the edge
            else:
                assert got <= oracle * h.growth * (1 + 1e-9), (q, oracle, got)

    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_merge_associative_commutative_exact(self, seed):
        rng = np.random.default_rng(seed)
        parts = []
        for _ in range(3):
            h = _hist()
            for v in rng.lognormal(mean=-1.0, sigma=2.5,
                                   size=int(rng.integers(1, 60))):
                h.observe(v)
            parts.append(h)
        a, b, c = parts
        left = a.copy().merge(b).merge(c)
        right = a.copy().merge(b.copy().merge(c))
        swapped = c.copy().merge(a).merge(b)
        for other in (right, swapped):
            assert left._counts == other._counts
            assert left.count == other.count
            assert left.min == other.min and left.max == other.max
            assert left.sum == pytest.approx(other.sum)
        assert left.count == a.count + b.count + c.count

    def test_merge_rejects_different_ladders(self):
        with pytest.raises(ValueError):
            _hist().merge(_hist(n_buckets=64))

    def test_overflow_quantile_is_exact_max(self):
        h = _hist(n_buckets=8)
        top = h.upper_edge(h.n_buckets - 2)
        h.observe(top * 1e6)
        assert h.quantile(0.99) == top * 1e6

    def test_to_dict_roundtrip_shape(self):
        h = _hist()
        for v in (1e-5, 1e-3, 1e6):
            h.observe(v)
        d = h.to_dict()
        assert d["count"] == 3 and d["max"] == 1e6
        assert sum(n for _, n in d["buckets"]) == 3
        assert d["buckets"][-1][0] is None  # overflow le


# ---------------------------------------------------------------------------
# Exporters + dump CLI
# ---------------------------------------------------------------------------


def _sample_registry():
    reg = Registry()
    reg.counter("train.iterations").inc(40)
    reg.counter("kernel.launches", kernel="dense_predict").inc(3)
    reg.gauge("train.objective").set(1.25)
    h = reg.histogram("serve.latency_seconds", bucket="all")
    for v in (1e-4, 2e-3, 0.5):
        h.observe(v)
    return reg


class TestExport:
    def test_prometheus_text(self):
        text = tm.to_prometheus(_sample_registry())
        assert "# TYPE repro_train_iterations_total counter" in text
        assert "repro_train_iterations_total 40.0" in text
        assert 'repro_kernel_launches_total{kernel="dense_predict"} 3.0' in text
        assert "repro_train_objective 1.25" in text
        assert 'le="+Inf"' in text
        assert 'repro_serve_latency_seconds_count{bucket="all"} 3' in text
        # cumulative buckets are non-decreasing
        cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
                if line.startswith("repro_serve_latency_seconds_bucket")]
        assert cums == sorted(cums) and cums[-1] == 3

    def test_jsonl_roundtrip_and_schema(self, tmp_path):
        path = tmp_path / "t.jsonl"
        n = tm.dump_jsonl(_sample_registry(), path, ts=123.0)
        recs = tm.read_jsonl(path)
        assert len(recs) == n == 4
        assert {r["kind"] for r in recs} == {"counter", "gauge", "histogram"}
        assert all(r["ts"] == 123.0 for r in recs)
        env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
        proc = subprocess.run(
            [sys.executable, str(REPO / "tools" / "check_telemetry_schema.py"),
             "--selftest", str(path)],
            capture_output=True, text=True, env=env)
        assert proc.returncode == 0, proc.stdout + proc.stderr

    def test_jsonl_sink_streams_spans(self, tmp_path):
        path = tmp_path / "events.jsonl"
        reg = Registry()
        with tm.JsonlSink(path) as sink:
            reg.attach_sink(sink)
            with reg.span("publish.seconds", iteration=7):
                pass
        (rec,) = tm.read_jsonl(path)
        assert rec["kind"] == "span" and rec["fields"] == {"iteration": 7}
        assert rec["seconds"] >= 0

    def test_dump_cli(self, tmp_path, capsys):
        path = tmp_path / "t.jsonl"
        tm.dump_jsonl(_sample_registry(), path, ts=5.0)
        assert tm_dump.main([str(path)]) == 0
        out = capsys.readouterr().out
        assert "train.iterations" in out and "serve.latency_seconds" in out
        prom = tmp_path / "snap.prom"
        assert tm_dump.main([str(path), "--prometheus", str(prom)]) == 0
        assert "repro_train_iterations_total 40.0" in prom.read_text()


# ---------------------------------------------------------------------------
# Training telemetry: bit-identity + trace decoding
# ---------------------------------------------------------------------------


class TestTrainTelemetry:
    def test_validate(self):
        assert tm.validate_telemetry(None) is None
        with pytest.raises(ValueError):
            tm.validate_telemetry(tm.TrainTelemetry(every=0))
        with pytest.raises(ValueError):
            tm.validate_telemetry(tm.TrainTelemetry(slots=0))

    def _assert_bit_identical(self, r_on, r_off):
        assert np.array_equal(np.asarray(r_on.W), np.asarray(r_off.W))
        assert np.array_equal(np.asarray(r_on.w_consensus),
                              np.asarray(r_off.w_consensus))
        assert np.array_equal(np.asarray(r_on.objective_trace),
                              np.asarray(r_off.objective_trace))
        assert r_on.iters == r_off.iters

    def test_dense_bit_identical_and_trace(self):
        X, y = _toy_parts()
        cfg = _cfg()
        r_off = gadget_train(X, y, cfg)
        r_on = gadget_train(X, y, cfg, telemetry=tm.TrainTelemetry())
        self._assert_bit_identical(r_on, r_off)
        assert r_off.telemetry is None
        tr = r_on.telemetry
        assert tr.count == cfg.max_iters  # every=1, slots=256: nothing lost
        assert list(tr.iterations) == sorted(tr.iterations)
        assert np.all(np.asarray(tr.drops) == 0)  # no FaultPlan, no drops
        assert np.all(np.isfinite(np.asarray(tr.objective)))
        assert tr.final_iteration == r_on.iters
        assert tr.final_disagreement >= 0.0

    def test_faulty_bit_identical_and_leakage_visible(self):
        X, y = _toy_parts()
        cfg = _cfg(faults=FaultPlan(drop_prob=0.3, drop="message", seed=5))
        r_off = gadget_train(X, y, cfg)
        tele = tm.TrainTelemetry(every=1, slots=cfg.max_iters)
        r_on = gadget_train(X, y, cfg, telemetry=tele)
        self._assert_bit_identical(r_on, r_off)
        tr = r_on.telemetry
        assert tr.count == cfg.max_iters
        assert int(np.sum(tr.drops)) > 0
        assert float(np.min(tr.mass_min)) < 1.0  # message mode leaks mass

    def test_sparse_bit_identical(self):
        ds = svm_datasets.make_dataset("reuters", scale=0.03, seed=0,
                                       sparse=True)
        Pe, yp, nc = svm_datasets.partition(ds.X_train, ds.y_train, 4, seed=3)
        cfg = _cfg(lam=ds.lam, max_iters=8, check_every=4)
        r_off = gadget_train(Pe, jnp.asarray(yp), cfg, n_counts=nc)
        r_on = gadget_train(Pe, jnp.asarray(yp), cfg, n_counts=nc,
                            telemetry=tm.TrainTelemetry())
        self._assert_bit_identical(r_on, r_off)

    def test_stream_bit_identical_and_segment_drops_match_ring(self):
        X, y = _toy_parts()
        cfg = _cfg(faults=FaultPlan(drop_prob=0.2, drop="message", seed=9))
        segs_off = list(gadget_train_stream(X, y, cfg, segment_iters=4))
        segs_on = list(gadget_train_stream(X, y, cfg, segment_iters=4,
                                           telemetry=tm.TrainTelemetry()))
        assert len(segs_on) == len(segs_off)
        for s_on, s_off in zip(segs_on, segs_off):
            assert np.array_equal(np.asarray(s_on.W), np.asarray(s_off.W))
            assert s_off.telemetry is None and s_on.telemetry is not None
            assert s_on.telemetry.mass_min <= s_on.telemetry.mass_max <= 1.0
        ring = gadget_train(X, y, cfg,
                            telemetry=tm.TrainTelemetry(
                                every=1, slots=cfg.max_iters)).telemetry
        assert int(np.sum(ring.drops)) == sum(
            s.telemetry.drops for s in segs_on)

    def test_ring_wraps_keep_latest(self):
        X, y = _toy_parts()
        cfg = _cfg(max_iters=12)
        tr = gadget_train(X, y, cfg,
                          telemetry=tm.TrainTelemetry(every=1,
                                                      slots=5)).telemetry
        assert tr.count == 5
        assert list(tr.iterations) == [8, 9, 10, 11, 12]

    def test_publish_trace_writes_gauges(self):
        X, y = _toy_parts()
        reg = Registry()
        tr = gadget_train(X, y, _cfg(),
                          telemetry=tm.TrainTelemetry()).telemetry
        tm.publish_trace(tr, registry=reg)
        assert reg.value("train.final_disagreement") == tr.final_disagreement
        assert reg.value("train.objective") == tr.objective[-1]
        assert reg.value("train.fault_drops") == 0

    def test_train_registry_accounting(self):
        tm.reset()
        X, y = _toy_parts()
        gadget_train(X, y, _cfg(max_iters=8, check_every=8))
        reg = tm.default_registry()
        assert reg.value("train.iterations") == 8
        assert reg.value("train.gossip_bytes") > 0
        tm.reset()


# ---------------------------------------------------------------------------
# Per-node telemetry leaves (observatory inputs)
# ---------------------------------------------------------------------------


class TestPerNodeTelemetry:
    def test_default_carries_no_node_rings(self):
        X, y = _toy_parts()
        tr = gadget_train(X, y, _cfg(),
                          telemetry=tm.TrainTelemetry()).telemetry
        assert tr.node_disagreement is None
        assert tr.node_mass is None and tr.node_drops is None

    def test_per_node_bit_identical_and_decode_matches_host(self):
        """per_node=True perturbs nothing (bit-identical trajectory) and the
        decoded leaves agree with host references: row-max of the per-node
        disagreement IS the scalar ring, the final row matches
        ``||W_i - w_consensus||`` within 1e-5, and fault-free mass is
        exactly 1 everywhere."""
        X, y = _toy_parts()
        cfg = _cfg(check_every=1)
        r_off = gadget_train(X, y, cfg)
        r_on = gadget_train(X, y, cfg,
                            telemetry=tm.TrainTelemetry(
                                every=1, slots=cfg.max_iters, per_node=True))
        assert np.array_equal(np.asarray(r_on.W), np.asarray(r_off.W))
        assert np.array_equal(np.asarray(r_on.w_consensus),
                              np.asarray(r_off.w_consensus))
        tr = r_on.telemetry
        assert tr.node_disagreement.shape == (cfg.max_iters, 4)
        np.testing.assert_array_equal(tr.node_disagreement.max(axis=1),
                                      np.asarray(tr.disagreement))
        host_ref = np.linalg.norm(
            np.asarray(r_on.W, np.float64)
            - np.asarray(r_on.w_consensus, np.float64), axis=1)
        np.testing.assert_allclose(tr.node_disagreement[-1], host_ref,
                                   atol=1e-5)
        np.testing.assert_array_equal(tr.node_mass,
                                      np.ones_like(tr.node_mass))
        assert not tr.node_drops.any()

    def test_per_node_drop_rows_sum_to_scalar_ring(self):
        X, y = _toy_parts()
        cfg = _cfg(check_every=1,
                   faults=FaultPlan(drop_prob=0.3, drop="message", seed=5))
        tr = gadget_train(X, y, cfg,
                          telemetry=tm.TrainTelemetry(
                              every=1, slots=cfg.max_iters,
                              per_node=True)).telemetry
        assert int(np.sum(tr.node_drops)) > 0
        np.testing.assert_array_equal(tr.node_drops.sum(axis=1),
                                      np.asarray(tr.drops))
        # message drops destroy mass somewhere in the fleet
        assert float(tr.node_mass.min()) < 1.0


# ---------------------------------------------------------------------------
# Kernel accounting
# ---------------------------------------------------------------------------


class TestKernelAccounting:
    def test_launch_cost_local(self):
        cost = hinge_ops.launch_cost("local_half_step", B=4, d=8)
        assert cost == {"launches": 2, "bytes": 400, "flops": 144}

    def test_launch_cost_unknown_kind(self):
        with pytest.raises(ValueError):
            hinge_ops.launch_cost("warp_drive")

    def test_record_launch_increments(self):
        reg = Registry()
        hinge_ops.record_launch("local_half_step", 3, registry=reg, B=4, d=8)
        assert reg.value("kernel.launches", kernel="local_half_step") == 6
        assert reg.value("kernel.bytes", kernel="local_half_step") == 1200
        hinge_ops.record_launch("ell_predict", registry=reg,
                                blocks_visited=2, B=4, k=3, C=2, blk_d=8,
                                n_blocks_max=6)
        assert reg.value("kernel.blocks_visited", kernel="ell_predict") == 2

    def test_maybe_record_skips_under_trace(self):
        tm.reset()

        def f(x):
            hinge_ops._maybe_record("local_half_step", x, B=2, d=4)
            return x

        jax.jit(f)(jnp.ones(3))  # traced probe: no side effect
        assert tm.default_registry().get("kernel.launches",
                                         kernel="local_half_step") is None
        f(np.ones(3))  # eager probe: records
        assert tm.default_registry().value(
            "kernel.launches", kernel="local_half_step") == 2
        tm.reset()


# ---------------------------------------------------------------------------
# Batcher soak: bounded memory, histogram-backed stats
# ---------------------------------------------------------------------------


class TestBatcherSoak:
    def test_soak_flat_memory_over_10k_submits(self):
        t = [0.0]

        def clock():
            t[0] += 1e-4
            return t[0]

        buckets = (bat.Bucket(4, 4, 2), bat.Bucket(4, 8, 4))
        mb = bat.MicroBatcher(buckets, clock)

        def score_fn(b, cols, vals):
            return (np.zeros(b.rows, np.float32), np.zeros(b.rows, np.int32))

        rng = np.random.default_rng(0)

        def footprint():
            return (len(mb.registry._series),
                    tuple(len(h._counts) for _, _, h in mb.registry.series()
                          if h.kind == "histogram"))

        baseline = None
        for chunk in range(100):
            for _ in range(100):
                nnz = int(rng.integers(1, 8))
                mb.submit(np.arange(nnz), np.ones(nnz))
            mb.drain(score_fn)
            if chunk == 4:
                baseline = footprint()
        # the old bug: a per-request list grew forever. Now the only state
        # is fixed-size histograms — the series census after 10k submits is
        # identical to the one after 500.
        assert footprint() == baseline
        assert not hasattr(mb, "_done")
        assert mb.pending == 0 and not mb._undelivered
        st_ = mb.stats()
        assert st_["requests"] == 10_000
        assert 0 < st_["latency_p50_ms"] <= st_["latency_p90_ms"] \
            <= st_["latency_p99_ms"]
        per = st_["per_bucket_latency_ms"]
        assert set(per) == {"k4", "k8"}
        assert sum(v["count"] for v in per.values()) == 10_000

    def test_stats_backcompat_keys(self):
        mb = bat.MicroBatcher((bat.Bucket(2, 4, 2),))
        for key in ("requests", "batches", "padded_rows", "pad_fraction",
                    "latency_p50_ms", "latency_p99_ms", "queries_per_sec",
                    "drain_seconds"):
            assert key in mb.stats()
        assert math.isnan(mb.stats()["latency_p50_ms"])  # nothing drained

    def test_shared_registry_folds_series(self):
        reg = Registry()
        mb = bat.MicroBatcher((bat.Bucket(2, 4, 2),), registry=reg)
        mb.submit([0, 1], [1.0, 1.0])
        mb.drain(lambda b, c, v: (np.zeros(b.rows, np.float32),
                                  np.zeros(b.rows, np.int32)))
        assert reg.value("serve.batches", bucket="k4") == 1
        assert reg.get("serve.latency_seconds", bucket="all").count == 1
