"""Deliverable (f): per-architecture smoke tests — every assigned arch as a
REDUCED variant (<=2 layers + pattern tail, d_model<=512, <=4 experts) runs
one forward + one train step on CPU with shape and finiteness assertions;
decoders additionally run a decode step against a cache."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch import input_specs as ispecs
from repro.launch import steps as steps_mod
from repro.models.transformer import Model

B, S = 2, 32


def _batch(cfg):
    return ispecs.make_host_batch(cfg, B, S, key=jax.random.PRNGKey(7))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= max(2, len(cfg.block_pattern)) and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    model = Model(cfg)
    tcfg = steps_mod.TrainerConfig(optimizer="sgd", lr=1e-2, total_steps=3,
                                   warmup_steps=1)
    state = steps_mod.make_train_state(model, tcfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)

    logits, aux = model.forward(state["params"], batch)
    # patches layout: P prefix + (S - P) text = S total positions
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    step_fn = jax.jit(steps_mod.make_train_step(model, tcfg))
    new_state, metrics = step_fn(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    changed = jax.tree.map(lambda a, b: bool(jnp.any(a != b)),
                           state["params"], new_state["params"])
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).supports_decode()])
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, 64, jnp.float32)
    logits, new_cache = jax.jit(model.decode_step)(
        params, jnp.zeros((B, 1), jnp.int32), cache, jnp.int32(3))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


def test_encoder_has_no_decode():
    cfg = get_config("hubert-xlarge").reduced()
    with pytest.raises(ValueError, match="encoder-only"):
        Model(cfg).init_cache(2, 8)


def test_full_configs_exact():
    """The 10 full configs carry the exact assigned hyperparameters."""
    expect = {
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "rwkv6-3b": (32, 2560, 0, 0, 8960, 65536),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    }
    for arch, (L, D, H, KV, F, V) in expect.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == \
            (L, D, H, KV, F, V), arch
    assert get_config("mixtral-8x22b").moe.n_experts == 8
    assert get_config("mixtral-8x22b").moe.top_k == 2
    q = get_config("qwen2-moe-a2.7b").moe
    assert (q.n_experts, q.top_k, q.d_shared) == (60, 4, 5632)
