"""End-user CLI smoke tests: the train/serve drivers as actually invoked."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run(mod, *args, timeout=420):
    return subprocess.run([sys.executable, "-m", mod, *args],
                          capture_output=True, text=True, timeout=timeout,
                          env=ENV, cwd=REPO)


def test_train_cli_allreduce(tmp_path):
    p = _run("repro.launch.train", "--arch", "llama3-8b", "--steps", "12",
             "--batch", "4", "--seq", "32", "--d-model", "64",
             "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "6")
    assert p.returncode == 0, p.stdout[-1500:] + p.stderr[-800:]
    assert "improved" in p.stdout
    assert any(d.startswith("step_") for d in os.listdir(tmp_path / "ck"))


def test_train_cli_gossip():
    p = _run("repro.launch.train", "--arch", "rwkv6-3b", "--steps", "10",
             "--batch", "4", "--seq", "32", "--d-model", "64",
             "--consensus", "gossip", "--n-replicas", "2")
    assert p.returncode == 0, p.stdout[-1500:] + p.stderr[-800:]
    assert "consensus=gossip" in p.stdout


def test_serve_cli():
    p = _run("repro.launch.serve", "--arch", "llama3-8b", "--batch", "2",
             "--prompt-len", "8", "--gen", "4", "--d-model", "64")
    assert p.returncode == 0, p.stdout[-1500:] + p.stderr[-800:]
    assert "ms/tok" in p.stdout


def test_serve_cli_encoder_graceful():
    p = _run("repro.launch.serve", "--arch", "hubert-xlarge")
    assert p.returncode == 0
    assert "encoder-only" in p.stdout
