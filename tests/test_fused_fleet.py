"""Fused fleet half-step kernel + collapsed gossip mixing.

Three acceptance surfaces:
  * collapsed mixing products are exactly the linear fold of the sequential
    per-round scan (property-tested over every topology, node count, round
    count and iteration offset),
  * the fused fleet kernel matches the pure-jnp oracle at non-block-multiple
    (B, d) shapes, padded rows and all,
  * the fused GADGET path end-to-end (gadget_train, cfg.fused=True — the
    default) agrees with both the unfused PR 1 path and the host-loop
    reference oracle, including under non-uniform ``n_counts`` partitions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology as topo
from repro.core.gadget import GadgetConfig, gadget_train, gadget_train_reference
from repro.core.push_sum import collapse_rounds, mix_collapsed, mix_rounds
from repro.kernels.hinge_subgrad import ops as hinge_ops
from repro.kernels.hinge_subgrad.ref import fleet_half_step_ref
from tests.conftest import make_separable


# ---------------------------------------------------------------------------
# Collapsed mixing == sequential mix_rounds (property test, shim-compatible)
# ---------------------------------------------------------------------------


@settings(max_examples=16, deadline=None)
@given(st.sampled_from(list(topo.DETERMINISTIC_TOPOLOGIES)),
       st.integers(2, 13), st.integers(1, 6), st.integers(1, 9))
def test_collapsed_products_match_sequential_deterministic(topology, n, R, t):
    """build_product_stack entry (t-1) % period must act exactly like the R
    scanned rounds of iteration t for every deterministic topology."""
    rng = np.random.default_rng(n * 100 + R * 10 + t)
    v = jnp.asarray(rng.normal(size=(n, 5)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=n).astype(np.float32))

    stack = topo.build_matrix_stack(topology, n)
    idx = ((t - 1) * R + np.arange(R)) % stack.shape[0]
    v_seq, w_seq = mix_rounds(v, w, jnp.asarray(stack[idx]))

    pstack = topo.build_product_stack(topology, n, R)
    assert pstack.shape == (topo.product_period(topology, n, R), n, n)
    P = jnp.asarray(pstack[(t - 1) % pstack.shape[0]])
    v_col, w_col = mix_collapsed(v, w, P)

    np.testing.assert_allclose(np.asarray(v_seq), np.asarray(v_col), atol=1e-5)
    np.testing.assert_allclose(np.asarray(w_seq), np.asarray(w_col), atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(st.integers(2, 13), st.integers(1, 6), st.integers(0, 99))
def test_collapse_rounds_matches_sequential_random_protocol(n, R, seed):
    """collapse_rounds folds the paper's random one-neighbor draws into one
    matrix with the same action as the R-round scan (mass conserved too)."""
    key = jax.random.PRNGKey(seed)
    Bs = jax.vmap(
        lambda r: topo.random_neighbor_matrix_device(jax.random.fold_in(key, r), n)
    )(jnp.arange(R))
    rng = np.random.default_rng(seed + 7)
    v = jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=n).astype(np.float32))

    v_seq, w_seq = mix_rounds(v, w, Bs)
    P = collapse_rounds(Bs)
    v_col, w_col = mix_collapsed(v, w, P)

    np.testing.assert_allclose(np.asarray(v_seq), np.asarray(v_col), atol=1e-5)
    np.testing.assert_allclose(np.asarray(w_seq), np.asarray(w_col), atol=1e-5)
    np.testing.assert_allclose(float(jnp.sum(w_col)), float(jnp.sum(w)), rtol=1e-5)


def test_product_stack_period_shrinks_stack():
    # exponential at n=16 has round period 4; R=4 folds a whole cycle into ONE
    # uploaded matrix per iteration (period 1) — exact averaging, 4x smaller.
    pstack = topo.build_product_stack("exponential", 16, 4)
    assert pstack.shape[0] == 1
    x = np.arange(16, dtype=np.float32)
    np.testing.assert_allclose(pstack[0] @ x, np.full(16, x.mean()), atol=1e-5)
    # co-prime R walks every offset: period stays T
    assert topo.build_product_stack("exponential", 16, 3).shape[0] == 4
    # static graphs always collapse to a single product
    assert topo.build_product_stack("ring", 7, 5).shape[0] == 1


# ---------------------------------------------------------------------------
# Fused fleet kernel vs jnp oracle (padding sweep)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,B,d", [
    (4, 8, 128),     # exact block multiples
    (3, 5, 130),     # both axes padded
    (6, 1, 7),       # single-row batch, tiny d
    (2, 13, 513),    # odd everything
    (1, 8, 96),      # single node
])
@pytest.mark.parametrize("project", [True, False])
def test_fleet_half_step_padding_matches_oracle(m, B, d, project):
    """The fused kernel pads B to sublane and d to lane multiples; padded rows
    are masked via the shared padded_row_mask helper and the d-pad is sliced
    off — must match the unpadded oracle at non-multiple shapes."""
    rng = np.random.default_rng(m * 10000 + B * 100 + d)
    X = jnp.asarray(rng.normal(size=(m, B, d)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=(m, B))).astype(np.float32))
    W = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32) * 0.1)
    t = jnp.float32(7.0)
    got = hinge_ops.fleet_half_step(W, X, y, lam=1e-3, t=t, project=project,
                                    interpret=True)
    want = fleet_half_step_ref(W, X, y, 1e-3, t, project=project)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-5)


def test_fleet_half_step_nonzero_pad_rows_are_masked():
    """Unlike local_half_step, the fleet kernel masks explicitly — a padded
    row is dropped even if the caller's padding carried garbage y. Feed a
    shape where padding exists and check the oracle on the valid prefix."""
    rng = np.random.default_rng(3)
    m, B, d = 2, 3, 40  # B pads 3 -> 8, d pads 40 -> 128
    X = jnp.asarray(rng.normal(size=(m, B, d)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=(m, B))).astype(np.float32))
    W = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32) * 0.1)
    got = hinge_ops.fleet_half_step(W, X, y, lam=1e-2, t=jnp.float32(3.0),
                                    interpret=True)
    want = fleet_half_step_ref(W, X, y, 1e-2, jnp.float32(3.0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_fleet_half_step_tile_budget_fallback(monkeypatch):
    """Tiles above FLEET_TILE_BUDGET_BYTES take the blocked two-kernel path —
    same math, no whole-tile VMEM residency."""
    rng = np.random.default_rng(9)
    m, B, d = 2, 9, 260
    X = jnp.asarray(rng.normal(size=(m, B, d)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=(m, B))).astype(np.float32))
    W = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32) * 0.1)
    monkeypatch.setattr(hinge_ops, "FLEET_TILE_BUDGET_BYTES", 1024)
    got = hinge_ops.fleet_half_step(W, X, y, lam=1e-3, t=jnp.float32(5.0),
                                    interpret=True)
    want = fleet_half_step_ref(W, X, y, 1e-3, jnp.float32(5.0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_padded_row_mask_invariant():
    mask = hinge_ops.padded_row_mask(8, 5)
    np.testing.assert_array_equal(np.asarray(mask),
                                  [True] * 5 + [False] * 3)


# ---------------------------------------------------------------------------
# End-to-end: fused path vs PR 1 path vs reference; non-uniform n_counts
# ---------------------------------------------------------------------------


def _partition(X, y, m):
    n_i = len(y) // m
    return (jnp.asarray(X[: m * n_i].reshape(m, n_i, -1)),
            jnp.asarray(y[: m * n_i].reshape(m, n_i)))


def _cfg(**kw):
    base = dict(lam=1e-3, batch_size=4, gossip_rounds=3, topology="exponential",
                max_iters=150, check_every=75, epsilon=1e-8)
    base.update(kw)
    return GadgetConfig(**base)


@pytest.mark.parametrize("topology", ["exponential", "torus", "random"])
def test_fused_path_matches_unfused_path(topology):
    X, y, _ = make_separable(n=1000, d=10, seed=2)
    Xp, yp = _partition(X, y, 5)
    fused = gadget_train(Xp, yp, _cfg(topology=topology, fused=True))
    seq = gadget_train(Xp, yp, _cfg(topology=topology, fused=False))
    assert fused.iters == seq.iters
    np.testing.assert_allclose(np.asarray(fused.w_consensus),
                               np.asarray(seq.w_consensus), atol=1e-5)
    np.testing.assert_allclose(np.asarray(fused.W), np.asarray(seq.W), atol=1e-5)


def _nonuniform_parts(seed=1, m=4, n_max=50, d=8):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=d)
    counts = rng.integers(n_max // 3, n_max + 1, size=m)
    counts[0] = n_max  # keep the padded width tight against one full node
    X = np.zeros((m, n_max, d), np.float32)
    y = np.zeros((m, n_max), np.float32)
    for i, c in enumerate(counts):
        Xi = rng.normal(size=(c, d)).astype(np.float32)
        X[i, :c] = Xi
        y[i, :c] = np.sign(Xi @ w_true)
    return jnp.asarray(X), jnp.asarray(y), counts


def test_nonuniform_counts_device_matches_reference():
    Xp, yp, counts = _nonuniform_parts()
    cfg = _cfg(max_iters=100, check_every=50)
    dev = gadget_train(Xp, yp, cfg, n_counts=counts)
    ref = gadget_train_reference(Xp, yp, cfg, n_counts=counts)
    assert dev.iters == ref.iters
    np.testing.assert_allclose(np.asarray(dev.w_consensus),
                               np.asarray(ref.w_consensus), atol=1e-5)
    np.testing.assert_allclose(dev.objective_trace, ref.objective_trace, rtol=1e-5)


def test_nonuniform_counts_weight_the_consensus():
    Xp, yp, counts = _nonuniform_parts(seed=5)
    res = gadget_train(Xp, yp, _cfg(max_iters=60, check_every=30),
                       n_counts=counts)
    want = (np.asarray(res.W) * counts[:, None]).sum(0) / counts.sum()
    np.testing.assert_allclose(np.asarray(res.w_consensus), want, atol=1e-5)
    assert np.all(np.isfinite(res.objective_trace))


def test_uniform_counts_match_default_api():
    X, y, _ = make_separable(n=600, d=8, seed=3)
    Xp, yp = _partition(X, y, 4)
    cfg = _cfg(max_iters=80, check_every=40)
    a = gadget_train(Xp, yp, cfg)
    b = gadget_train(Xp, yp, cfg, n_counts=[Xp.shape[1]] * 4)
    np.testing.assert_allclose(np.asarray(a.w_consensus),
                               np.asarray(b.w_consensus), atol=1e-6)
    np.testing.assert_allclose(a.objective_trace, b.objective_trace, rtol=1e-6)


def test_n_counts_validation():
    Xp, yp, _ = _nonuniform_parts()
    cfg = _cfg(max_iters=10, check_every=10)
    with pytest.raises(ValueError, match="n_counts"):
        gadget_train(Xp, yp, cfg, n_counts=[1, 2])
    with pytest.raises(ValueError, match="n_counts"):
        gadget_train(Xp, yp, cfg, n_counts=[0, 10, 10, 10])
    with pytest.raises(ValueError, match="n_counts"):
        gadget_train_reference(Xp, yp, cfg, n_counts=[999] * 4)
