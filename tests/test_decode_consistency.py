"""Token-by-token decode must reproduce the full-sequence forward logits —
pins KV-cache indexing, RoPE positions, SWA ring masks, and recurrent-state
threading. MoE archs are checked under dropless capacity (capacity drops
legitimately differ between prefill and decode batch statistics)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import Model

CASES = ["llama3-8b", "recurrentgemma-9b", "rwkv6-3b", "mixtral-8x22b",
         "qwen2-moe-a2.7b", "llava-next-mistral-7b", "nemotron-4-15b"]


@pytest.mark.parametrize("arch", CASES)
def test_decode_matches_forward(arch):
    S = 24
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:  # dropless so routing is identical
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B = 2
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)

    if cfg.embed_kind == "patches":
        P_ = min(cfg.n_prefix_embeds, 8)
        cfg2 = dataclasses.replace(cfg, n_prefix_embeds=P_)
        model = Model(cfg2)
        patch = 0.02 * jax.random.normal(jax.random.PRNGKey(3), (B, P_, cfg.d_model))
        batch = {"patch_embeds": patch, "tokens": toks, "targets": toks}
        logits_full, _ = model.forward(params, batch)
        logits_full = logits_full[:, P_:]
        # decode continues AFTER the image prefix: replay prefix tokens too
        # (the image part itself is exercised via forward only)
        pytest.skip("vlm decode covered by smoke test; prefix replay is N/A")
    else:
        batch = {"tokens": toks, "targets": toks}
        logits_full, _ = model.forward(params, batch)

    cache = model.init_cache(B, S, jnp.float32)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, cache = step(params, toks[:, t:t + 1], cache, jnp.int32(t))
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_full),
                               atol=5e-4, rtol=1e-3)


def test_swa_ring_cache_long_context():
    """Decode far past the window: ring cache must equal a fresh big cache."""
    cfg = get_config("mixtral-8x22b").reduced()
    cfg = dataclasses.replace(
        cfg, window=8,
        moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 40
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab_size)
    logits_full, _ = model.forward(params, {"tokens": toks, "targets": toks})
    cache = model.init_cache(B, S, jnp.float32)  # ring: size = window 8 << 40
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, cache = step(params, toks[:, t:t + 1], cache, jnp.int32(t))
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(logits_full), atol=5e-4, rtol=1e-3)
