"""Property tests on model invariants (hypothesis where shapes vary).

* causality: a decoder's logits at position t never depend on tokens > t
* SWA locality: tokens further than `window` back have no influence
* MoE: combine weights per token sum to <= 1; dropless routing is exact
* RG-LRU: bounded state for decay in (0,1); zero-input fixed point
* encoder is NOT causal (bidirectional sanity)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import rglru as G
from repro.models.transformer import Model


def _logits(model, params, toks):
    out, _ = model.forward(params, {"tokens": toks, "targets": toks})
    return out


@settings(max_examples=6, deadline=None)
@given(st.integers(2, 20), st.integers(0, 4))
def test_causality_dense(t_edit, seed):
    cfg = get_config("llama3-8b").reduced(n_layers=2, d_model=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 24
    toks = jax.random.randint(jax.random.PRNGKey(seed), (1, S), 0, cfg.vocab_size)
    base = _logits(model, params, toks)
    # edit a future token; logits strictly before the edit must not move
    edited = toks.at[0, t_edit].set((toks[0, t_edit] + 1) % cfg.vocab_size)
    out = _logits(model, params, edited)
    np.testing.assert_allclose(np.asarray(base[0, :t_edit]),
                               np.asarray(out[0, :t_edit]), atol=1e-5)


def test_causality_recurrent_families():
    for arch in ("rwkv6-3b", "recurrentgemma-9b"):
        cfg = get_config(arch).reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        S, t_edit = 18, 9
        toks = jax.random.randint(jax.random.PRNGKey(2), (1, S), 0, cfg.vocab_size)
        base = _logits(model, params, toks)
        edited = toks.at[0, t_edit].set((toks[0, t_edit] + 3) % cfg.vocab_size)
        out = _logits(model, params, edited)
        np.testing.assert_allclose(np.asarray(base[0, :t_edit]),
                                   np.asarray(out[0, :t_edit]), atol=1e-5, err_msg=arch)


def test_swa_locality():
    """With window w, logits at position t are independent of tokens <= t-w."""
    cfg = get_config("mixtral-8x22b").reduced()
    cfg = dataclasses.replace(
        cfg, window=4, n_layers=1,
        moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, S), 0, cfg.vocab_size)
    base = _logits(model, params, toks)
    edited = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    out = _logits(model, params, edited)
    # with 1 layer and window 4, positions >= 4 can't see token 0
    np.testing.assert_allclose(np.asarray(base[0, 4:]), np.asarray(out[0, 4:]),
                               atol=1e-5)


def test_encoder_is_bidirectional():
    cfg = get_config("hubert-xlarge").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 12
    frames = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, S, cfg.d_model))
    base, _ = model.forward(params, {"frames": frames, "targets": jnp.zeros((1, S), jnp.int32),
                                     "mask": jnp.ones((1, S), bool)})
    edited = frames.at[0, -1].add(1.0)
    out, _ = model.forward(params, {"frames": edited, "targets": jnp.zeros((1, S), jnp.int32),
                                    "mask": jnp.ones((1, S), bool)})
    # editing the LAST frame must change EARLIER outputs (bidirectional)
    assert float(jnp.max(jnp.abs(base[0, 0] - out[0, 0]))) > 1e-6


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.integers(8, 64), st.integers(4, 32))
def test_rglru_state_bounded(B, S, D):
    """|h_t| <= max|b|/(1-max a) for a in (0,1) — BIBO stability."""
    rng = np.random.default_rng(S)
    a = jnp.asarray(rng.uniform(0.0, 0.95, (B, S, D)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(B, S, D)).astype(np.float32))
    h = G.rglru_scan_ref(a, b, jnp.zeros((B, D)))
    bound = float(jnp.max(jnp.abs(b))) / (1.0 - 0.95) + 1e-4
    assert float(jnp.max(jnp.abs(h))) <= bound


def test_moe_combine_weights_subunit():
    """Renormalized top-k combine weights sum to <= 1 per token (== 1 when
    nothing is dropped)."""
    from repro.models import moe as M
    from repro.models.config import MoEConfig

    cfg = MoEConfig(n_experts=4, top_k=2, d_expert=32, capacity_factor=4.0)
    p = M.init_moe(jax.random.PRNGKey(0), 16, cfg, "gated_silu")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, aux = M.moe_apply(p, x, cfg, "gated_silu")
    assert y.shape == x.shape
    assert float(jnp.sum(aux.expert_fraction)) <= 1.0 + 1e-5
    # dropless: zero input -> zero routed output (experts are gated mlps)
    y0, _ = M.moe_apply(p, jnp.zeros_like(x), cfg, "gated_silu")
    assert float(jnp.max(jnp.abs(y0))) < 1e-6
