"""Fault injection through the training stack: the fused device path, the
host-loop reference, the segmented stream, crash-resume, and the mesh step all
under one FaultPlan — parity, mass accounting, frozen dead nodes, and
bit-identical kill-and-resume."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.faults import FaultPlan
from repro.core.gadget import (GadgetConfig, TrainState, gadget_train,
                               gadget_train_reference, gadget_train_stream)


def _toy_parts(m=4, n_i=16, d=24, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=d)
    X = rng.normal(size=(m * n_i, d)).astype(np.float32)
    y = np.sign(X @ w_true).astype(np.float32)
    return jnp.asarray(X.reshape(m, n_i, d)), jnp.asarray(y.reshape(m, n_i))


def _cfg(**kw):
    base = dict(lam=1e-2, batch_size=2, gossip_rounds=2, max_iters=16,
                check_every=4, epsilon=0.0, use_kernels=False)
    base.update(kw)
    return GadgetConfig(**base)


# ---------------------------------------------------------------------------
# Fused device path vs host-loop reference (the parity oracle)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topology", ["exponential", "random"])
@pytest.mark.parametrize("drop", ["link", "message"])
def test_fused_matches_reference_under_faults(topology, drop):
    """The acceptance-criteria parity: fused training with faults matches the
    host-loop reference to <= 1e-5 on the consensus weights — the fault layer
    composes with the collapsed-product gossip path without changing what is
    computed."""
    X, y = _toy_parts()
    cfg = _cfg(topology=topology,
               faults=FaultPlan(drop_prob=0.2, drop=drop, seed=5))
    dev = gadget_train(X, y, cfg)
    ref = gadget_train_reference(X, y, cfg)
    assert dev.iters == ref.iters
    diff = float(jnp.max(jnp.abs(dev.w_consensus - ref.w_consensus)))
    assert diff <= 1e-5, diff
    W_diff = float(jnp.max(jnp.abs(dev.W - ref.W)))
    assert W_diff <= 1e-5, W_diff


def test_dead_nodes_parity_and_reference_mass():
    X, y = _toy_parts()
    cfg = _cfg(faults=FaultPlan(drop_prob=0.1, drop="link",
                                dead_nodes=(1,), seed=2))
    dev = gadget_train(X, y, cfg)
    ref = gadget_train_reference(X, y, cfg)
    assert float(jnp.max(jnp.abs(dev.w_consensus - ref.w_consensus))) <= 1e-5
    # both paths account mass the same way
    np.testing.assert_allclose(dev.mass_trace, ref.mass_trace, atol=1e-5)


# ---------------------------------------------------------------------------
# Mass invariant
# ---------------------------------------------------------------------------


def test_mass_trace_conserved_without_faults_and_in_link_mode():
    X, y = _toy_parts()
    clean = gadget_train(X, y, _cfg())
    np.testing.assert_allclose(clean.mass_trace, 1.0, atol=1e-5)
    linked = gadget_train(
        X, y, _cfg(faults=FaultPlan(drop_prob=0.4, drop="link", seed=3)))
    assert linked.mass_trace.shape == clean.mass_trace.shape
    # ack'd links: exact conservation to float-sum tolerance, every check
    np.testing.assert_allclose(linked.mass_trace, 1.0, atol=1e-5)


def test_mass_trace_measures_message_leakage():
    X, y = _toy_parts()
    res = gadget_train(
        X, y, _cfg(faults=FaultPlan(drop_prob=0.4, drop="message", seed=3)))
    assert np.all(res.mass_trace <= 1.0 + 1e-6)
    assert res.mass_trace.min() < 0.999  # leakage actually observed


# ---------------------------------------------------------------------------
# Dead nodes are bit-frozen
# ---------------------------------------------------------------------------


def test_dead_node_weights_bit_frozen():
    X, y = _toy_parts()
    res = gadget_train(
        X, y, _cfg(faults=FaultPlan(dead_nodes=(0, 2), seed=1)))
    W = np.asarray(res.W)
    # dead rows never left their (zero) initialization — exactly
    np.testing.assert_array_equal(W[0], np.zeros_like(W[0]))
    np.testing.assert_array_equal(W[2], np.zeros_like(W[2]))
    # survivors trained
    assert float(np.abs(W[1]).max()) > 0
    assert float(np.abs(W[3]).max()) > 0


# ---------------------------------------------------------------------------
# Inert plans hit the perfect-network path bit-identically
# ---------------------------------------------------------------------------


def test_inert_plan_bit_identical_to_no_plan():
    X, y = _toy_parts()
    clean = gadget_train(X, y, _cfg())
    inert = gadget_train(
        X, y, _cfg(faults=FaultPlan(drop_prob=0.0, seed=99)))
    assert bool(jnp.all(clean.W == inert.W))
    np.testing.assert_array_equal(np.asarray(clean.w_consensus),
                                  np.asarray(inert.w_consensus))


def test_invalid_plan_rejected_at_train_entry():
    X, y = _toy_parts()
    with pytest.raises(ValueError):
        gadget_train(X, y, _cfg(faults=FaultPlan(drop_prob=1.5)))
    with pytest.raises(ValueError):
        gadget_train(X, y, _cfg(faults=FaultPlan(dead_nodes=(7,))))


# ---------------------------------------------------------------------------
# Stream + crash-resume under faults
# ---------------------------------------------------------------------------


def test_faulty_stream_bitmatches_train():
    X, y = _toy_parts()
    cfg = _cfg(faults=FaultPlan(drop_prob=0.3, drop="message",
                                dead_nodes=(3,), seed=8))
    ref = gadget_train(X, y, cfg)
    segs = list(gadget_train_stream(X, y, cfg, segment_iters=5))
    assert segs[-1].iteration == ref.iters
    assert bool(jnp.all(segs[-1].W == ref.W))
    np.testing.assert_array_equal(np.asarray(segs[-1].w_consensus),
                                  np.asarray(ref.w_consensus))


def test_kill_and_resume_bit_identical_under_faults():
    """The acceptance-criteria resume: stop after a segment, rebuild a
    TrainState, continue — final weights bit-match the uninterrupted faulty
    run (fault draws key on the global iteration, so the replayed stream is
    the same stream)."""
    X, y = _toy_parts()
    cfg = _cfg(faults=FaultPlan(drop_prob=0.25, drop="link", seed=4))
    full = list(gadget_train_stream(X, y, cfg, segment_iters=4))

    first = next(iter(gadget_train_stream(X, y, cfg, segment_iters=4)))
    ts = TrainState(iteration=first.iteration, W=first.W, W_sum=first.W_sum)
    resumed = list(gadget_train_stream(X, y, cfg, segment_iters=4, resume=ts))

    assert [s.iteration for s in resumed] == [s.iteration for s in full[1:]]
    assert bool(jnp.all(resumed[-1].W == full[-1].W))
    np.testing.assert_array_equal(np.asarray(resumed[-1].w_consensus),
                                  np.asarray(full[-1].w_consensus))


def test_resume_validation():
    X, y = _toy_parts()
    cfg = _cfg()
    bad_shape = TrainState(iteration=4, W=jnp.zeros((2, 3)),
                           W_sum=jnp.zeros((2, 3)))
    with pytest.raises(ValueError):
        next(gadget_train_stream(X, y, cfg, segment_iters=4, resume=bad_shape))
    m, d = X.shape[0], X.shape[-1]
    neg = TrainState(iteration=-1, W=jnp.zeros((m, d)), W_sum=jnp.zeros((m, d)))
    with pytest.raises(ValueError):
        next(gadget_train_stream(X, y, cfg, segment_iters=4, resume=neg))


# ---------------------------------------------------------------------------
# Mesh path (4 forced CPU devices, subprocess so the flag cannot leak)
# ---------------------------------------------------------------------------

MESH_FAULT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core.faults import FaultPlan
from repro.core.gadget import GadgetConfig, make_gadget_mesh_step

m, n_i, d = 4, 16, 24
rng = np.random.default_rng(0)
w_true = rng.normal(size=d)
X = rng.normal(size=(m, n_i, d)).astype(np.float32)
y = np.sign(X @ w_true).astype(np.float32)
mesh = Mesh(np.array(jax.devices()), ("nodes",))
cfg = GadgetConfig(lam=1e-2, batch_size=2, gossip_rounds=2, use_kernels=False)

def runner(step):
    def per_node(w, x, yl, keys, t):
        return step(w[0], x[0], yl[0], t, keys[0])[None]
    specs = (P("nodes"),) * 4 + (P(),)
    return jax.jit(shard_map(per_node, mesh=mesh, in_specs=specs,
                             out_specs=P("nodes"), check_rep=False))

def train(step, iters=6):
    W = jnp.zeros((m, d), jnp.float32)
    run = runner(step)
    Xd, yd = jnp.asarray(X), jnp.asarray(y)
    for t in range(1, iters + 1):
        keys = jax.random.split(jax.random.fold_in(jax.random.PRNGKey(0), t), m)
        W = run(W, Xd, yd, keys, jnp.int32(t))
    return np.asarray(W)

# 1. inert plan is bit-identical to the unmasked collective path
W_clean = train(make_gadget_mesh_step(cfg, {"nodes": m}))
W_inert = train(make_gadget_mesh_step(
    cfg._replace(faults=FaultPlan(drop_prob=0.0, seed=7)), {"nodes": m}))
assert np.array_equal(W_clean, W_inert), "inert plan perturbed the mesh step"

# 2. dead shard bit-frozen at init, survivors train
W_dead = train(make_gadget_mesh_step(
    cfg._replace(faults=FaultPlan(dead_nodes=(2,), seed=7)), {"nodes": m}))
assert np.array_equal(W_dead[2], np.zeros(d, np.float32)), "dead shard moved"
assert all(np.abs(W_dead[i]).max() > 0 for i in (0, 1, 3)), "survivor frozen"

# 3. faulty links: run completes, weights finite + distinct from clean
W_drop = train(make_gadget_mesh_step(
    cfg._replace(faults=FaultPlan(drop_prob=0.5, drop="message", seed=7)),
    {"nodes": m}))
assert np.all(np.isfinite(W_drop)), "faulty mesh run produced non-finite w"
assert np.abs(W_drop).max() > 0 and not np.array_equal(W_drop, W_clean)

# 4. invalid plan rejected at build time (linearized id out of range)
try:
    make_gadget_mesh_step(cfg._replace(faults=FaultPlan(dead_nodes=(4,))),
                          {"nodes": m})
    raise SystemExit("out-of-range dead node accepted")
except ValueError:
    pass
print("MESH_FAULTS_OK")
"""


class TestMeshFaults:
    def test_mesh_step_faults_multidevice(self, tmp_path):
        """The ppermute fault path on a real 4-device mesh: inert plans are
        bit-inert, dead shards freeze, link drops degrade gracefully, and
        plan validation happens at build time."""
        import os
        import subprocess
        import sys
        script = tmp_path / "mesh_faults.py"
        script.write_text(MESH_FAULT_SCRIPT)
        repo = __file__.rsplit("/tests/", 1)[0]
        env = {**os.environ, "PYTHONPATH": f"{repo}/src"}
        p = subprocess.run([sys.executable, str(script)], capture_output=True,
                           text=True, timeout=540, env=env)
        assert p.returncode == 0, p.stdout[-2000:] + p.stderr[-2000:]
        assert "MESH_FAULTS_OK" in p.stdout
