"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode.
One test class per kernel (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import gqa_flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hinge_subgrad.ops import pegasos_step
from repro.kernels.hinge_subgrad.ref import pegasos_step_ref
from repro.kernels.rglru_scan.ops import linear_recurrence
from repro.kernels.rglru_scan.ref import scan_ref as rglru_ref
from repro.kernels.rwkv6_scan.ops import wkv
from repro.kernels.rwkv6_scan.ref import scan_ref as wkv_ref

RNG = np.random.default_rng(0)


class TestHingeSubgrad:
    @pytest.mark.parametrize("B,d", [(8, 32), (64, 100), (128, 512), (300, 777), (5, 2048)])
    @pytest.mark.parametrize("dtype", [np.float32])
    def test_matches_ref(self, B, d, dtype):
        X = jnp.asarray(RNG.normal(size=(B, d)).astype(dtype))
        y = jnp.asarray(np.sign(RNG.normal(size=B)).astype(dtype))
        w = jnp.asarray(RNG.normal(size=d).astype(dtype)) * 0.1
        t = jnp.float32(3.0)
        w1, l1 = pegasos_step(w, X, y, lam=1e-3, t=t, interpret=True)
        w2, l2 = pegasos_step_ref(w, X, y, 1e-3, t)
        np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=2e-5)
        np.testing.assert_allclose(float(l1), float(l2), atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 60), st.integers(2, 90), st.integers(1, 50))
    def test_property_random_shapes(self, B, d, t):
        X = jnp.asarray(RNG.normal(size=(B, d)).astype(np.float32))
        y = jnp.asarray(np.sign(RNG.normal(size=B) + 0.1).astype(np.float32))
        w = jnp.zeros(d, jnp.float32)
        w1, _ = pegasos_step(w, X, y, lam=1e-2, t=jnp.float32(t), interpret=True)
        w2, _ = pegasos_step_ref(w, X, y, 1e-2, jnp.float32(t))
        np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=2e-5)
        # ball projection invariant
        assert float(jnp.linalg.norm(w1)) <= 1.0 / np.sqrt(1e-2) + 1e-3


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,hkv,dh,causal,window", [
        (2, 128, 4, 2, 64, True, 0),
        (1, 256, 4, 1, 64, True, 64),
        (2, 64, 2, 2, 32, False, 0),
        (1, 128, 8, 4, 128, True, 32),
        (1, 96, 2, 1, 16, True, 0),      # non-128-multiple seq
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, s, h, hkv, dh, causal, window, dtype):
        q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, dh), dtype)
        k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, dh), dtype)
        v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, dh), dtype)
        out = gqa_flash_attention(q, k, v, causal=causal, window=window,
                                  blk_q=32, blk_k=32, interpret=True)
        n_rep = h // hkv
        ke = jnp.repeat(k, n_rep, axis=2)
        ve = jnp.repeat(v, n_rep, axis=2)
        qf = jnp.moveaxis(q, 2, 1).reshape(b * h, s, dh)
        kf = jnp.moveaxis(ke, 2, 1).reshape(b * h, s, dh)
        vf = jnp.moveaxis(ve, 2, 1).reshape(b * h, s, dh)
        ref = jnp.moveaxis(attention_ref(qf, kf, vf, causal=causal, window=window)
                           .reshape(b, h, s, dh), 1, 2)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=tol)


class TestRGLRUScan:
    @pytest.mark.parametrize("B,S,D,bs,bd", [
        (2, 64, 128, 16, 64), (1, 100, 70, 32, 32), (3, 256, 256, 128, 128),
        (1, 17, 130, 8, 128),
    ])
    def test_matches_ref(self, B, S, D, bs, bd):
        a = jnp.asarray(RNG.uniform(0.8, 0.999, size=(B, S, D)).astype(np.float32))
        b = jnp.asarray(RNG.normal(size=(B, S, D)).astype(np.float32))
        h1 = linear_recurrence(a, b, blk_s=bs, blk_d=bd, interpret=True)
        h2 = rglru_ref(a, b)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)

    @settings(max_examples=8, deadline=None)
    @given(st.integers(1, 3), st.integers(2, 70), st.integers(2, 80))
    def test_property(self, B, S, D):
        a = jnp.asarray(RNG.uniform(0.0, 1.0, size=(B, S, D)).astype(np.float32))
        b = jnp.asarray(RNG.normal(size=(B, S, D)).astype(np.float32))
        h1 = linear_recurrence(a, b, blk_s=16, blk_d=32, interpret=True)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(rglru_ref(a, b)), atol=1e-5)


class TestRWKV6Scan:
    @pytest.mark.parametrize("B,S,H,n,bs", [
        (2, 64, 2, 16, 16), (1, 100, 3, 32, 32), (2, 128, 2, 64, 64), (1, 33, 1, 8, 16),
    ])
    def test_matches_ref(self, B, S, H, n, bs):
        r, k, v = (jnp.asarray(RNG.normal(size=(B, S, H, n)).astype(np.float32)) * 0.3
                   for _ in range(3))
        w = jnp.asarray(RNG.uniform(0.8, 0.999, size=(B, S, H, n)).astype(np.float32))
        u = jnp.asarray(RNG.normal(size=(H, n)).astype(np.float32)) * 0.1
        o1 = wkv(r, k, v, w, u, blk_s=bs, interpret=True)
        o2 = wkv_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
