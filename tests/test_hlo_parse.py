"""Unit tests for the HLO collective parser (pure text -> bytes accounting).
These pin the byte conventions the roofline tables are built on."""
from repro.launch.hlo_parse import parse_collectives


def test_all_reduce_iota_groups():
    line = "  %all-reduce = f32[128]{0} all-reduce(%x), replica_groups=[32,16]<=[512]"
    out = parse_collectives(line)
    # 128 floats = 512 B; ring AR moves 2*(g-1)/g * O with g=16
    assert out["bytes_by_op"]["all-reduce"] == 2 * 512 * 15 / 16
    assert out["count_by_op"]["all-reduce"] == 1


def test_all_gather_explicit_groups():
    line = ("  %all-gather = bf16[64,32]{1,0} all-gather(%x), "
            "replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}")
    out = parse_collectives(line)
    # 64*32 bf16 = 4096 B; (g-1)/g with g=4
    assert out["bytes_by_op"]["all-gather"] == 4096 * 3 / 4


def test_collective_permute_counts_output():
    line = "  %collective-permute = f32[16,16]{1,0} collective-permute(%x), channel_id=7"
    out = parse_collectives(line)
    assert out["bytes_by_op"]["collective-permute"] == 16 * 16 * 4


def test_reduce_scatter():
    line = ("  %reduce-scatter = f32[8]{0} reduce-scatter(%x), "
            "replica_groups=[2,8]<=[16], dimensions={0}")
    out = parse_collectives(line)
    assert out["bytes_by_op"]["reduce-scatter"] == 32 * (8 - 1)


def test_non_collective_lines_ignored():
    txt = "\n".join([
        "  %dot = f32[128,128]{1,0} dot(%a, %b)",
        "  %add = f32[4]{0} add(%x, %y)",
        "ENTRY %main { ... }",
    ])
    out = parse_collectives(txt)
    assert out["total_bytes"] == 0 and not out["count_by_op"]


def test_multiple_ops_summed():
    txt = "\n".join([
        "  %all-gather.1 = f32[4]{0} all-gather(%x), replica_groups={{0,1}}",
        "  %all-gather.2 = f32[4]{0} all-gather(%y), replica_groups={{0,1}}",
    ])
    out = parse_collectives(txt)
    assert out["count_by_op"]["all-gather"] == 2
    assert out["bytes_by_op"]["all-gather"] == 2 * 16 * 1 / 2
